"""Data pipeline: deterministic synthetic streams + the single source of truth
for model input signatures.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every model
input of an (architecture x input-shape) pair — weak-type-correct, shardable,
no device allocation — used by the AOT dry-run (DESIGN.md deliverable e).
``make_batch`` produces concrete arrays with the same structure for real
training/serving; a structural test asserts they agree.

Modality frontends are stubs per the assignment: for [audio]/[vlm] archs the
pipeline emits precomputed frame/patch embeddings of the right shape.
"""
from __future__ import annotations

from typing import Any, Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.kvcache import cache_logical_axes, init_cache


def _positions_struct(cfg, B, S, concrete: bool):
    if cfg.rope_style == "mrope":
        if concrete:
            # text-style M-RoPE positions: all three components equal
            p = np.broadcast_to(np.arange(S, dtype=np.int32)[None, None], (3, B, S))
            return jnp.asarray(p)
        return jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    if concrete:
        return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return jax.ShapeDtypeStruct((B, S), jnp.int32)


def _enc_dec_split(cfg, S: int) -> Tuple[int, int]:
    """Training shape for enc-dec archs: split seq budget into enc/dec halves."""
    return S // 2, S // 2


def make_train_batch(cfg: ModelConfig, shape: ShapeConfig, *, concrete: bool,
                     rng: np.random.Generator = None) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    if cfg.is_encoder_decoder:
        S_enc, S_dec = _enc_dec_split(cfg, S)
        if concrete:
            batch["enc_embeds"] = jnp.asarray(
                rng.standard_normal((B, S_enc, cfg.d_model), np.float32) * 0.02, jnp.bfloat16)
            batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_dec)), jnp.int32)
            batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_dec)), jnp.int32)
        else:
            batch["enc_embeds"] = jax.ShapeDtypeStruct((B, S_enc, cfg.d_model), jnp.bfloat16)
            batch["tokens"] = jax.ShapeDtypeStruct((B, S_dec), jnp.int32)
            batch["labels"] = jax.ShapeDtypeStruct((B, S_dec), jnp.int32)
        batch["positions"] = _positions_struct(cfg, B, S_dec, concrete)
        return batch
    if cfg.input_mode == "embeddings":
        if concrete:
            batch["embeds"] = jnp.asarray(
                rng.standard_normal((B, S, cfg.d_model), np.float32) * 0.02, jnp.bfloat16)
        else:
            batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    else:
        if concrete:
            batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if concrete:
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    else:
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    batch["positions"] = _positions_struct(cfg, B, S, concrete)
    return batch


def make_prefill_batch(cfg: ModelConfig, shape: ShapeConfig, *, concrete: bool,
                       rng: np.random.Generator = None) -> Dict[str, Any]:
    b = make_train_batch(cfg, shape, concrete=concrete, rng=rng)
    b.pop("labels", None)
    return b


def make_decode_inputs(cfg: ModelConfig, shape: ShapeConfig, *, concrete: bool,
                       rng: np.random.Generator = None):
    """Returns (cache, tokens [B,1], pos scalar). Cache holds shape.seq_len."""
    B, S = shape.global_batch, shape.seq_len
    enc_len = _enc_dec_split(cfg, S)[0] if cfg.is_encoder_decoder else 0
    cache = init_cache(cfg, B, S, enc_len=enc_len, mode="zeros" if concrete else "shape")
    if concrete:
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        pos = jnp.asarray(S - 1, jnp.int32)
    else:
        tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, tokens, pos


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    if shape.kind == "train":
        return {"batch": make_train_batch(cfg, shape, concrete=False)}
    if shape.kind == "prefill":
        return {"batch": make_prefill_batch(cfg, shape, concrete=False)}
    cache, tokens, pos = make_decode_inputs(cfg, shape, concrete=False)
    return {"cache": cache, "tokens": tokens, "pos": pos}


def make_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
    rng = np.random.default_rng(seed)
    if shape.kind == "train":
        return {"batch": make_train_batch(cfg, shape, concrete=True, rng=rng)}
    if shape.kind == "prefill":
        return {"batch": make_prefill_batch(cfg, shape, concrete=True, rng=rng)}
    cache, tokens, pos = make_decode_inputs(cfg, shape, concrete=True, rng=rng)
    return {"cache": cache, "tokens": tokens, "pos": pos}


def batch_logical_axes(cfg: ModelConfig, shape: ShapeConfig):
    """Logical axes mirroring input_specs, for in_shardings."""
    pos_axes = (None, "act_batch", "act_seq") if cfg.rope_style == "mrope" else ("act_batch", "act_seq")
    if shape.kind in ("train", "prefill"):
        axes: Dict[str, Any] = {}
        if cfg.is_encoder_decoder:
            axes["enc_embeds"] = ("act_batch", None, "act_embed")
            axes["tokens"] = ("act_batch", "act_seq")
            if shape.kind == "train":
                axes["labels"] = ("act_batch", "act_seq")
            axes["positions"] = pos_axes
            return {"batch": axes}
        if cfg.input_mode == "embeddings":
            axes["embeds"] = ("act_batch", "act_seq", "act_embed")
        else:
            axes["tokens"] = ("act_batch", "act_seq")
        if shape.kind == "train":
            axes["labels"] = ("act_batch", "act_seq")
        axes["positions"] = pos_axes
        return {"batch": axes}
    S = shape.seq_len
    enc_len = _enc_dec_split(cfg, S)[0] if cfg.is_encoder_decoder else 0
    return {
        "cache": cache_logical_axes(cfg, shape.global_batch, S, enc_len),
        "tokens": ("act_batch", None),
        "pos": (),
    }


def synthetic_token_stream(vocab_size: int, batch: int, seq_len: int, seed: int = 0,
                           pattern_len: int = 16, noise: float = 0.02
                           ) -> Iterator[Dict[str, jnp.ndarray]]:
    """Deterministic LM data: each sequence tiles a random `pattern_len`-token
    pattern (plus a little noise) — an induction-head task a transformer
    cracks within a few hundred steps, so end-to-end training drivers have a
    visible convergence signal. labels = next-token."""
    rng = np.random.default_rng(seed)
    pattern_len = min(pattern_len, max(seq_len // 4, 2))
    # Zipf-skewed vocabulary: gives an immediately-learnable unigram signal
    # (loss falls within tens of steps) on top of the copy structure.
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = 1.0 / (ranks + 8.0)
    probs /= probs.sum()
    while True:
        pat = rng.choice(vocab_size, size=(batch, pattern_len), p=probs)
        reps = (seq_len + 1) // pattern_len + 1
        seq = np.tile(pat, (1, reps))[:, : seq_len + 1]
        noise_tok = rng.integers(0, vocab_size, seq.shape)
        mask = rng.random(seq.shape) < noise
        seq = np.where(mask, noise_tok, seq).astype(np.int32)
        yield {
            "tokens": jnp.asarray(seq[:, :-1]),
            "labels": jnp.asarray(seq[:, 1:]),
            "positions": jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32)[None],
                                          (batch, seq_len)),
        }
