from repro.data.pipeline import (
    batch_logical_axes,
    input_specs,
    make_batch,
    synthetic_token_stream,
)

__all__ = ["batch_logical_axes", "input_specs", "make_batch", "synthetic_token_stream"]
