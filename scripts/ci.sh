#!/usr/bin/env bash
# CI entrypoints (see tests/README.md for the tier matrix).
#
#   scripts/ci.sh           tier-1: the full suite (the repo's contract)
#   scripts/ci.sh --smoke   fast subset: kernels + a 4-device engine smoke
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# tier-matrix completeness: every tests/test_*.py must have a row in
# tests/README.md — fail FAST (before any pytest run) so a new test module
# can't silently ship undocumented / untiered
python - <<'EOF'
import pathlib, re, sys

tests = pathlib.Path("tests")
readme = (tests / "README.md").read_text()
listed = set(re.findall(r"test_\w+\.py", readme))
present = {p.name for p in tests.glob("test_*.py")}
missing = sorted(present - listed)
if missing:
    sys.exit("tests/README.md tier matrix is missing rows for: "
             + ", ".join(missing))
stale = sorted(listed - present)
if stale:
    sys.exit("tests/README.md lists test modules that do not exist: "
             + ", ".join(stale))
print(f"tier matrix complete: {len(present)} test modules all listed")
EOF

if [[ "${1:-}" == "--smoke" ]]; then
    python -m pytest -x -q tests/test_kernels.py tests/test_exec_protocols.py
    # 4-device engine smoke: one exec model x {sync, async} vs the oracle
    XLA_FLAGS=--xla_force_host_platform_device_count=4 python - <<'EOF'
import jax
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph

g = sbm_graph(96, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
for proto in ("sync", "epoch_adaptive"):
    eng = DistGNNEngine(g, cfg=EngineConfig(execution="p2p", protocol=proto,
                                            hidden=16, lr=0.3))
    ld, _ = eng.train(3)
    lr_, _ = eng.train(3, reference=True)
    err = max(abs(a - b) for a, b in zip(ld, lr_))
    assert err < 1e-4, (proto, err)
    print(f"smoke OK p2p/{proto}: oracle err {err:.2e}")
EOF
    # 4-device node-wise MINI-BATCH engine smoke (budget < 60 s): sampled
    # batches + resident cache vs the oracle, one compile per fanout config
    XLA_FLAGS=--xla_force_host_platform_device_count=4 python - <<'EOF'
import jax
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph

g = sbm_graph(96, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
eng = DistGNNEngine(g, cfg=EngineConfig(
    execution="p2p", batching="node_wise", batch_size=8, fanouts=(3, 3),
    hidden=16, lr=0.3, cache_policy="static_degree", cache_capacity=12))
ld, _ = eng.train(3)
lr_, _ = eng.train(3, reference=True)
err = max(abs(a - b) for a, b in zip(ld, lr_))
assert err < 1e-4, err
assert eng._jit_mb_step._cache_size() == 1, eng._jit_mb_step._cache_size()
print(f"smoke OK node_wise minibatch p2p+cache: oracle err {err:.2e}, "
      f"1 compile, {eng.comm_stats.cache_hit_bytes} cache-hit bytes")
EOF
    # 4-device PIPELINED node-wise minibatch smoke: prefetch depth 2 +
    # chunked broadcast exchange; the pipelined epoch must be bitwise-
    # identical to the blocking one (losses, params, CommStats)
    XLA_FLAGS=--xla_force_host_platform_device_count=4 python - <<'EOF'
import os
import jax
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph

g = sbm_graph(96, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
eng = DistGNNEngine(g, cfg=EngineConfig(
    execution="broadcast", batching="node_wise", batch_size=8,
    fanouts=(3, 3), hidden=16, lr=0.3, exchange_chunks=4, prefetch_depth=2))
s1, l1, t1 = eng.run_epoch_minibatch(4, schedule="conventional")
stats1 = eng.comm_stats
s2, l2, t2 = eng.run_epoch_minibatch(4, schedule="pipelined")
assert l1 == l2, (l1, l2)
eq = jax.tree_util.tree_map(lambda a, b: bool((a == b).all()),
                            s1["params"], s2["params"])
assert all(jax.tree_util.tree_leaves(eq)), eq
assert eng.comm_stats == stats1
assert eng._jit_mb_step._cache_size() == 1
if (os.cpu_count() or 1) >= 2:  # overlap needs a core for the sampler lane
    assert t2.busy() > t2.wall, (t2.busy(), t2.wall)
print(f"smoke OK pipelined node_wise broadcast+chunks: bitwise == blocking, "
      f"wall {t2.wall:.3f}s vs lanes {t2.busy():.3f}s")
EOF
    # 4-device PROCESS-prefetch pipelined smoke (ISSUE 9): the GIL-free
    # sampler pool + shared-memory batch ring; the process-pipelined epoch
    # must be bitwise-identical to the blocking one, and closing the pool
    # must leave /dev/shm clean
    XLA_FLAGS=--xla_force_host_platform_device_count=4 python - <<'EOF'
import dataclasses, os
import jax
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph

g = sbm_graph(96, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
eng = DistGNNEngine(g, cfg=EngineConfig(
    execution="broadcast", batching="node_wise", batch_size=8,
    fanouts=(3, 3), hidden=16, lr=0.3, exchange_chunks=4, prefetch_depth=2,
    num_sample_workers=2))
s1, l1, t1 = eng.run_epoch_minibatch(4, schedule="conventional")
stats1 = dataclasses.replace(eng.comm_stats)
s2, l2, t2 = eng.run_epoch_minibatch(4, schedule="pipelined",
                                     prefetch_mode="process")
assert l1 == l2, (l1, l2)
eq = jax.tree_util.tree_map(lambda a, b: bool((a == b).all()),
                            s1["params"], s2["params"])
assert all(jax.tree_util.tree_leaves(eq)), eq
assert eng.comm_stats == stats1
assert eng._jit_mb_step._cache_size() == 1
eng.close_prefetch_pool()
litter = [f for f in os.listdir("/dev/shm") if f.startswith("repro-")]
assert litter == [], litter
print(f"smoke OK process-prefetch pipelined: bitwise == blocking, "
      f"shm clean, wall {t2.wall:.3f}s")
EOF
    # streaming-partition smoke (ISSUE 9): chunked edge ingest must rebuild
    # the engine's in-memory edge-cut layout array-for-array
    XLA_FLAGS=--xla_force_host_platform_device_count=4 python - <<'EOF'
import numpy as np
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph
from repro.core.partition.streaming import (
    GraphEdgeChunks,
    build_streaming_layout,
)

g = sbm_graph(96, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
eng = DistGNNEngine(g, cfg=EngineConfig(hidden=8))
lay = build_streaming_layout(
    GraphEdgeChunks(g, 64), eng.part.assignment, eng.k, g.num_vertices,
    features=g.features, labels=g.labels, train_mask=g.train_mask,
    test_mask=g.test_mask)
assert (lay.nb, lay.Vp, lay.K) == (eng.nb, eng.Vp, eng.K)
np.testing.assert_array_equal(lay.new_of_old, eng.new_of_old)
np.testing.assert_array_equal(lay.ids, eng.ids_global)
np.testing.assert_array_equal(lay.mask, np.asarray(eng.mask))
np.testing.assert_array_equal(lay.X, np.asarray(eng.store._table))
np.testing.assert_array_equal(lay.bmask, np.asarray(eng.bmask))
print(f"smoke OK streaming partition: chunk=64 identical to in-memory "
      f"build, peak_transient={lay.peak_transient_bytes} bytes")
EOF
    # 4-device MODEL-AXIS smoke: SAGE (edge-cut p2p — self features resident)
    # and GAT (vertex-cut broadcast — SDDMM logits + two-pass max/sum replica
    # softmax sync) vs their extended single-device oracles
    XLA_FLAGS=--xla_force_host_platform_device_count=4 python - <<'EOF'
import jax
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph

g = sbm_graph(96, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
for model, kw in (("sage", dict(execution="p2p")),
                  ("gat", dict(execution="broadcast",
                               partition_family="vertex_cut",
                               vertex_cut="cartesian2d"))):
    eng = DistGNNEngine(g, cfg=EngineConfig(model=model, hidden=16, lr=0.3,
                                            **kw))
    ld, _ = eng.train(3)
    lr_, _ = eng.train(3, reference=True)
    err = max(abs(a - b) for a, b in zip(ld, lr_))
    assert err < 1e-4, (model, err)
    assert eng._jit_step._cache_size() == 1
    print(f"smoke OK model={model} {kw}: oracle err {err:.2e}, 1 compile")
EOF
    # 4-device TRAINABLE-FEATURES smoke: layer-0 rows as learnable embedding
    # store rows — node-wise p2p with the cache as a live hot-row overlay,
    # row-sparse AdamW vs the dense-table oracle, embed-grad bytes accounted
    XLA_FLAGS=--xla_force_host_platform_device_count=4 python - <<'EOF'
import jax
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph

g = sbm_graph(96, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
eng = DistGNNEngine(g, cfg=EngineConfig(
    execution="p2p", batching="node_wise", batch_size=8, fanouts=(3, 3),
    hidden=16, lr=0.3, cache_policy="static_degree", cache_capacity=12,
    trainable_features=True, embed_lr=0.05))
ld, _ = eng.train(3)
lr_, _ = eng.train(3, reference=True)
err = max(abs(a - b) for a, b in zip(ld, lr_))
assert err < 1e-4, err
assert eng._jit_mb_step._cache_size() == 1, eng._jit_mb_step._cache_size()
assert eng.comm_stats.embed_grad_bytes > 0
print(f"smoke OK trainable node_wise p2p+overlay: oracle err {err:.2e}, "
      f"1 compile, {eng.comm_stats.embed_grad_bytes} embed-grad bytes")
EOF
    # 4-device VERTEX-CUT engine smoke: cartesian2d 2x2 cut, sync protocol,
    # replica-sync p2p GAS exchange vs the oracle + bytes accounting
    XLA_FLAGS=--xla_force_host_platform_device_count=4 python - <<'EOF'
import jax
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph

g = sbm_graph(96, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
eng = DistGNNEngine(g, cfg=EngineConfig(
    partition_family="vertex_cut", vertex_cut="cartesian2d",
    execution="p2p", protocol="sync", hidden=16, lr=0.3))
ld, _ = eng.train(3)
lr_, _ = eng.train(3, reference=True)
err = max(abs(a - b) for a, b in zip(ld, lr_))
assert err < 1e-4, err
assert eng._jit_step._cache_size() == 1, eng._jit_step._cache_size()
assert eng.comm_stats.replica_sync_bytes > 0
print(f"smoke OK vertex_cut cartesian2d 2x2 p2p/sync: oracle err {err:.2e}, "
      f"1 compile, replication {eng.layout.replication_factor():.2f}, "
      f"{eng.comm_stats.replica_sync_bytes} replica-sync bytes")
EOF
    # 4-device SERVING smoke (ISSUE 7): one layer-wise full-graph sweep vs
    # the oracle with the wire bytes cross-checked against the engine's own
    # cost model, then a few K-target queries through the GNNQueryEngine vs
    # the single-device reference round — one serve compile total
    XLA_FLAGS=--xla_force_host_platform_device_count=4 python - <<'EOF'
import jax
import numpy as np
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph
from repro.core.serving import GNNQueryEngine

g = sbm_graph(96, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
eng = DistGNNEngine(g, cfg=EngineConfig(
    execution="p2p", batching="node_wise", batch_size=8, fanouts=(3, 3),
    hidden=16, lr=0.3, cache_policy="static_degree", cache_capacity=12))
state, _, _ = eng.run_epoch_minibatch(3)
params = state["params"]
emb = eng.global_embeddings(eng.infer_full_graph(params=params))
ref = eng.global_embeddings(eng.infer_full_graph(params=params,
                                                 reference=True))
err = float(np.max(np.abs(emb - ref)))
assert err < 1e-4, err
assert eng.comm_stats.inference_bytes == eng.inference_bytes_per_sweep()
qe = GNNQueryEngine(eng, params)
rng = np.random.default_rng(0)
for _ in range(3):
    targets = rng.choice(g.num_vertices, 6, replace=False)
    per_dev = [[] for _ in range(eng.k)]
    for v in targets:
        per_dev[int(eng.part.assignment[v])].append(int(v))
    batch = qe.build_round([np.asarray(x, np.int64) for x in per_dev])
    H = np.asarray(qe.serve_round(batch))
    R = np.asarray(qe.reference_round(batch))
    for d, tg in enumerate(per_dev):
        if tg:
            qerr = float(np.max(np.abs(H[d, :len(tg)] - R[d, :len(tg)])))
            assert qerr < 1e-4, (d, qerr)
assert qe.num_compiles() == 1, qe.num_compiles()
print(f"smoke OK serving: sweep oracle err {err:.2e}, "
      f"{eng.comm_stats.inference_bytes} inference bytes == cost model, "
      f"{qe.stats.rounds} query rounds, 1 serve compile")
EOF
    # 4-device TELEMETRY smoke (ISSUE 8): traced train + serve — the Chrome
    # trace file parses, spans cover every configured step, and the per-step
    # CommStats fields equal the mirrored MetricRegistry counter totals
    XLA_FLAGS=--xla_force_host_platform_device_count=4 python - <<'EOF'
import dataclasses, json, os, tempfile
import jax
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph
from repro.core.serving import GNNQueryEngine

g = sbm_graph(96, num_blocks=4, p_in=0.08, p_out=0.01, seed=0)
eng = DistGNNEngine(g, cfg=EngineConfig(
    execution="p2p", batching="node_wise", batch_size=8, fanouts=(3, 3),
    hidden=16, lr=0.3, cache_policy="static_degree", cache_capacity=12))
tel = eng.enable_telemetry()
NB = 4
state, _, _ = eng.run_epoch_minibatch(NB, schedule="pipelined")
qe = GNNQueryEngine(eng, state["params"])
qe.query([1, 2, 3])
path = os.path.join(tempfile.mkdtemp(), "trace.json")
tel.write_chrome_trace(path)
with open(path) as f:
    trace = json.load(f)  # the artifact must parse as real JSON
xev = [e for e in trace["traceEvents"] if e["ph"] == "X"]
assert xev and all(set(("name", "ph", "ts", "dur", "pid", "tid")) <= set(e)
                   for e in xev)
for stage in ("sample", "extract", "train"):
    steps = {e["args"].get("step") for e in xev if e["name"] == stage}
    assert set(range(NB)) <= steps, (stage, steps)
for f in dataclasses.fields(eng.comm_stats):
    mirrored = tel.metrics.counter_total("comm." + f.name)
    assert mirrored == getattr(eng.comm_stats, f.name), (f.name, mirrored)
exch = sum(e["args"]["bytes"] for e in xev if e["name"] == "exchange")
assert exch == eng.comm_stats.total(), (exch, eng.comm_stats.total())
print(f"smoke OK telemetry: {len(xev)} trace events, all {NB} steps "
      f"spanned, comm counters == CommStats, exchange bytes {exch} == "
      f"total()")
EOF
    # 4-device HYBRID-CUT engine smoke (ISSUE 10): PowerLyra-style degree-
    # threshold family — low-degree halo exchange + hub replica-sync GAS —
    # vs the oracle, with the wire bytes cross-checked against the
    # standalone hybrid cost model
    XLA_FLAGS=--xla_force_host_platform_device_count=4 python - <<'EOF'
import jax
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import powerlaw_graph
from repro.core.partition.cost_models import hybrid_bytes_per_step

g = powerlaw_graph(96, avg_degree=8, seed=0)
eng = DistGNNEngine(g, cfg=EngineConfig(
    partition_family="hybrid", execution="p2p", hidden=16, lr=0.3))
ld, _ = eng.train(3)
lr_, _ = eng.train(3, reference=True)
err = max(abs(a - b) for a, b in zip(ld, lr_))
assert err < 1e-4, err
assert eng._jit_step._cache_size() == 1, eng._jit_step._cache_size()
lay = eng.playout
wire = eng.comm_stats.halo_bytes + eng.comm_stats.replica_sync_bytes
assert wire == 3 * hybrid_bytes_per_step(
    lay.halo_rows_exec if lay.halo_active else 0,
    lay._vc_rows_per_layer if lay.sync_active else 0, eng.dims)
print(f"smoke OK hybrid p2p thr={lay.cut.threshold:.1f}: oracle err "
      f"{err:.2e}, 1 compile, {int(lay.cut.hub.sum())} hubs, "
      f"{wire} wire bytes == cost model")
EOF
    # 4-device AUTOTUNER smoke (ISSUE 10): enumerate -> choose -> validate;
    # the chosen plan's predicted step bytes must reproduce EXACTLY in the
    # traced dryrun (ratio 1.0) or the planner raises PlanRejected
    XLA_FLAGS=--xla_force_host_platform_device_count=4 python - <<'EOF'
from repro.core.graph import powerlaw_graph
from repro.core.partition.autotune import autotune

g = powerlaw_graph(96, avg_degree=8, seed=0)
dims = [g.features.shape[1], 16, int(g.labels.max()) + 1]
plan, report = autotune(g, 4, dims, "gcn")
assert report["validation"]["ratio"] == 1.0, report["validation"]
assert len(report["candidates"]) >= 12
print(f"smoke OK autotune: chose {plan.label()} of "
      f"{len(report['candidates'])} candidates, "
      f"{plan.predicted_step_bytes} B/step validated at ratio 1.0")
EOF
else
    python -m pytest -x -q
fi
