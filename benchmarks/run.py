"""Benchmark harness: one function per paper table/figure analog.
Prints ``name,us_per_call,derived`` CSV (plus detailed rows to stderr).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run --only cache,staleness
"""
from __future__ import annotations

import argparse
import sys
import time


def _detail(rows):
    for r in rows:
        print("   ", r, file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--skip", default="")
    args = ap.parse_args()

    from benchmarks.bench_gnn import (
        bench_cache,
        bench_distributed_sampling,
        bench_partition,
        bench_protocol_costs,
        bench_staleness,
        bench_step_pipeline,
        bench_trainable_embeddings,
    )
    from benchmarks.bench_kernels import bench_kernels
    from benchmarks.bench_spmm_comm import bench_spmm_comm
    from benchmarks.roofline import roofline_table

    benches = {
        "partition": bench_partition,  # survey §4.2 table
        "cache": bench_cache,  # §5.1 cache policies
        "sampling": bench_distributed_sampling,  # §5.1 CSP / skewed
        "protocols": bench_protocol_costs,  # §7.1 comm volume
        "staleness": bench_staleness,  # §7.2 / Table 3
        "step_pipeline": bench_step_pipeline,  # ISSUE 4: pipelined hot path
        "trainable_embed": bench_trainable_embeddings,  # ISSUE 6: embed bytes
        "spmm_comm": bench_spmm_comm,  # §6.2.2 / Table 2 (CAGNET)
        "kernels": bench_kernels,  # Pallas kernel structural timing
        "roofline": lambda: roofline_table("experiments/dryrun"),  # deliverable g
    }
    only = set(filter(None, args.only.split(",")))
    skip = set(filter(None, args.skip.split(",")))
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        if (only and name not in only) or name in skip:
            continue
        t0 = time.perf_counter()
        try:
            rows, derived = fn()
            us = (time.perf_counter() - t0) * 1e6
            print(f"{name},{us:.0f},{derived}")
            print(f"== {name} ==", file=sys.stderr)
            _detail(rows)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},FAILED,{type(e).__name__}: {str(e)[:120]}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
