"""Survey Table 2 / CAGNET claim: collective bytes per distributed-SpMM
execution model, measured from lowered HLO on a forced-multi-device subprocess
(benchmarks keep the main process at 1 device)."""
from __future__ import annotations

import os
import subprocess
import sys
from typing import Dict, List, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = r"""
import jax, numpy as np, jax.numpy as jnp, json
from repro.core.graph import er_graph, sbm_graph
from repro.core.execution.spmm_models import (spmm_replicated, spmm_1d_broadcast,
    spmm_1d_ring, spmm_1d_p2p, spmm_2d_summa, spmm_15d, p2p_plan)
from repro.launch.hlo_analysis import collective_bytes

V, D = 512, 64
g = sbm_graph(V, num_blocks=8, p_in=0.04, p_out=0.002, seed=0)
# relabel vertices by a locality-aware partition so device row-blocks align
# with communities (what a real deployment does before distributing)
from repro.core.partition import PARTITIONERS
part = PARTITIONERS["metis_like"](g, 8)
order = np.argsort(part.assignment, kind="stable")
A_np = g.to_dense_adj()[np.ix_(order, order)]
A = jnp.asarray(A_np)
H = jnp.asarray(np.random.default_rng(0).standard_normal((V, D)).astype(np.float32))
m1 = jax.make_mesh((8,), ("w",))
m2 = jax.make_mesh((4, 2), ("r", "c"))
rows = []
def measure(name, fn, mesh, *extra):
    comp = jax.jit(lambda a, h: fn(mesh, a, h, *extra)).lower(A, H).compile()
    total, kinds = collective_bytes(comp.as_text())
    rows.append(dict(model=name, collective_bytes=int(total), by_kind=kinds))
measure("C:replicated", spmm_replicated, m1)
measure("CC:1d_broadcast", spmm_1d_broadcast, m1)
measure("CC:1d_ring(chunk)", spmm_1d_ring, m1)
plan = p2p_plan(A_np, 8)
measure("CC:1d_p2p(selective)", spmm_1d_p2p, m1, plan)
measure("CCR:2d_summa", spmm_2d_summa, m2)
measure("CCR:1.5d", spmm_15d, m2)
print("<<<JSON>>>")
print(json.dumps(rows))
"""


def bench_spmm_comm() -> Tuple[List[Dict], str]:
    import json

    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", _CODE], capture_output=True,
                          text=True, timeout=600, env=env)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    rows = json.loads(proc.stdout.split("<<<JSON>>>")[1])
    base = next(r for r in rows if r["model"] == "CC:1d_broadcast")["collective_bytes"]
    p2p = next(r for r in rows if "p2p" in r["model"])["collective_bytes"]
    return rows, f"p2p_vs_1d_broadcast={p2p / max(base, 1):.3f}"
