"""Per-kernel µs/call. On CPU these run the interpret-mode kernel (structural
check) AND the jnp oracle; the oracle timing is the meaningful CPU number,
interpret timing only proves the kernel executes."""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.ell_spmm import ell_spmm_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.sddmm import sddmm_pallas
from repro.kernels.wkv_chunk import wkv_chunk_pallas


def _time(fn, *args, repeats=3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats * 1e6


def bench_kernels() -> Tuple[List[Dict], str]:
    rng = np.random.default_rng(0)
    rows = []
    # ell_spmm
    V, K, D = 1024, 16, 128
    ids = jnp.asarray(rng.integers(0, V, (V, K)), jnp.int32)
    mask = jnp.asarray(rng.random((V, K)) < 0.7, jnp.float32)
    H = jnp.asarray(rng.standard_normal((V, D)), jnp.float32)
    oracle = jax.jit(lambda i, m, h: ref.ell_spmm_ref(i, m, h))
    rows.append(dict(kernel="ell_spmm", shape=f"V{V}xK{K}xD{D}",
                     oracle_us=round(_time(oracle, ids, mask, H), 1),
                     interpret_us=round(_time(
                         lambda *a: ell_spmm_pallas(*a, interpret=True),
                         ids, mask, H, repeats=1), 1)))
    # sddmm
    a_src = jnp.asarray(rng.standard_normal(D), jnp.float32)
    a_dst = jnp.asarray(rng.standard_normal(D), jnp.float32)
    oracle = jax.jit(lambda *a: ref.sddmm_ref(*a))
    rows.append(dict(kernel="sddmm", shape=f"V{V}xK{K}xD{D}",
                     oracle_us=round(_time(oracle, ids, mask, H, a_src, a_dst), 1),
                     interpret_us=round(_time(
                         lambda *a: sddmm_pallas(*a, interpret=True),
                         ids, mask, H, a_src, a_dst, repeats=1), 1)))
    # flash attention
    B, Hh, S, Dh = 1, 4, 512, 64
    q = jnp.asarray(rng.standard_normal((B, Hh, S, Dh)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, Hh, S, Dh)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, Hh, S, Dh)), jnp.bfloat16)
    oracle = jax.jit(lambda *a: ref.flash_attention_ref(*a))
    rows.append(dict(kernel="flash_attention", shape=f"B{B}H{Hh}S{S}D{Dh}",
                     oracle_us=round(_time(oracle, q, k, v), 1),
                     interpret_us=round(_time(
                         lambda *a: flash_attention_pallas(*a, interpret=True),
                         q, k, v, repeats=1), 1)))
    # wkv
    B2, H2, S2, K2 = 1, 4, 256, 64
    r = jnp.asarray(rng.standard_normal((B2, H2, S2, K2)) * 0.5, jnp.float32)
    kk = jnp.asarray(rng.standard_normal((B2, H2, S2, K2)) * 0.5, jnp.float32)
    vv = jnp.asarray(rng.standard_normal((B2, H2, S2, K2)) * 0.5, jnp.float32)
    g = jnp.asarray(-np.abs(rng.standard_normal((B2, H2, S2, K2))) * 0.3, jnp.float32)
    u = jnp.asarray(rng.standard_normal((H2, K2)) * 0.1, jnp.float32)
    oracle = jax.jit(lambda *a: ref.wkv_chunk_ref(*a))
    rows.append(dict(kernel="wkv_chunk", shape=f"B{B2}H{H2}S{S2}K{K2}",
                     oracle_us=round(_time(oracle, r, kk, vv, g, u), 1),
                     interpret_us=round(_time(
                         lambda *a: wkv_chunk_pallas(*a, interpret=True),
                         r, kk, vv, g, u, repeats=1), 1)))
    return rows, f"{len(rows)} kernels validated"
