"""GNN-side benchmarks — one per survey table/figure analog.

Each function returns (rows, derived_summary): rows are printable dicts; the
summary is one line for the CSV contract in run.py.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import full_graph_train, powerlaw_graph, sbm_graph
from repro.core.partition import PARTITIONERS
from repro.core.protocols import PROTOCOL_COSTS
from repro.core.sampling import (
    FIFOCache,
    analysis_cache,
    csp_sample,
    importance_cache,
    node_wise_sample,
    presampling_cache,
    pull_based_sample,
    simulate_hit_ratio,
    skewed_weighted_sample,
    static_degree_cache,
)


def bench_partition() -> Tuple[List[Dict], str]:
    """Survey §4.2 table: partition quality (cut, balance, train balance,
    comm volume) per partitioner, on a community graph and a power-law graph."""
    rows = []
    for gname, g in (("sbm", sbm_graph(400, num_blocks=8, p_in=0.06, p_out=0.003, seed=0)),
                     ("powerlaw", powerlaw_graph(400, avg_degree=10, seed=0))):
        for name in ("hash", "range", "ldg", "pagraph", "block", "bytegnn", "metis_like"):
            t0 = time.perf_counter()
            part = PARTITIONERS[name](g, 8)
            dt = time.perf_counter() - t0
            rows.append(dict(graph=gname, partitioner=name,
                             cut=round(part.edge_cut_fraction(g), 4),
                             balance=round(part.vertex_balance(), 3),
                             train_balance=round(part.train_balance(g), 3),
                             comm_rows=part.communication_volume(g),
                             seconds=round(dt, 3)))
    balanced = [r for r in rows if r["graph"] == "sbm" and r["balance"] < 1.5]
    best = min(balanced, key=lambda r: r["cut"])
    return rows, f"best_balanced_sbm_cut={best['partitioner']}:{best['cut']}"


def bench_cache() -> Tuple[List[Dict], str]:
    """Survey §5.1: hit ratio per cache policy (PaGraph/AliGraph/GNNLab/
    SALIENT++/BGL claims) at several capacities on a power-law graph."""
    g = powerlaw_graph(600, avg_degree=12, seed=1)
    rng = np.random.default_rng(0)
    train = np.where(g.train_mask)[0]

    def stream(seed=0):
        r = np.random.default_rng(seed)
        for _ in range(30):
            batch = r.choice(train, 16, replace=False)
            yield node_wise_sample(g, batch, (4, 4), r).layer_vertices[0]

    rows = []
    for cap_frac in (0.05, 0.15, 0.3):
        cap = int(cap_frac * g.num_vertices)
        random_ids = rng.choice(g.num_vertices, cap, replace=False)
        policies = {
            "random": lambda: random_ids,
            "degree(PaGraph)": lambda: static_degree_cache(g, cap),
            "importance(AliGraph)": lambda: importance_cache(g, cap),
            "presampling(GNNLab)": lambda: presampling_cache(g, cap),
            "analysis(SALIENT++)": lambda: analysis_cache(g, cap),
        }
        for name, fn in policies.items():
            hr = simulate_hit_ratio(fn(), stream())
            rows.append(dict(capacity=cap, policy=name, hit_ratio=round(hr, 4)))
        fifo = FIFOCache(cap)
        rows.append(dict(capacity=cap, policy="fifo(BGL)",
                         hit_ratio=round(fifo.run(stream()), 4)))
    top = max(rows, key=lambda r: r["hit_ratio"])
    return rows, f"best={top['policy']}@{top['capacity']}:{top['hit_ratio']}"


def bench_distributed_sampling() -> Tuple[List[Dict], str]:
    """Survey §5.1: DSP's CSP vs pull-based bytes; skewed-sampling locality."""
    g = powerlaw_graph(600, avg_degree=12, seed=2)
    part = PARTITIONERS["hash"](g, 8)
    rng = np.random.default_rng(0)
    targets = np.arange(256)
    rows = []
    _, pull = pull_based_sample(g, part, 0, targets, fanout=5, rng=rng)
    _, push = csp_sample(g, part, 0, targets, fanout=5, rng=rng)
    rows.append(dict(method="pull(DistDGL)", bytes=pull.total()))
    rows.append(dict(method="csp(DSP)", bytes=push.total(),
                     reduction=round(1 - push.total() / max(pull.total(), 1), 3)))
    for s in (1.0, 2.0, 4.0, 8.0):
        _, st, loc = skewed_weighted_sample(g, part, 0, targets, 5, s,
                                            np.random.default_rng(1))
        rows.append(dict(method=f"skewed(s={s})", bytes=st.total(),
                         locality=round(loc, 3)))
    return rows, f"csp_reduction={rows[1]['reduction']}"


def bench_protocol_costs() -> Tuple[List[Dict], str]:
    """Survey §7.1: per-protocol communication volume per layer."""
    g = powerlaw_graph(500, avg_degree=10, seed=3)
    part = PARTITIONERS["metis_like"](g, 8)
    rows = []
    for name, fn in PROTOCOL_COSTS.items():
        c = fn(g, part, 64)
        rows.append(dict(protocol=name, bytes_per_layer=c.bytes_per_layer,
                         messages=c.messages_per_layer))
    b = next(r for r in rows if r["protocol"] == "broadcast")["bytes_per_layer"]
    p = next(r for r in rows if r["protocol"] == "p2p")["bytes_per_layer"]
    return rows, f"p2p_vs_broadcast={p / max(b, 1):.3f}"


def bench_staleness() -> Tuple[List[Dict], str]:
    """Survey §7.2 / Table 3: accuracy + bytes pushed per staleness model
    (PipeGCN/SANCUS claim: bounded staleness ~ sync accuracy, less comm)."""
    g = sbm_graph(250, num_blocks=4, p_in=0.08, p_out=0.004, seed=4)
    rows = []
    sync = full_graph_train(g, epochs=50)
    rows.append(dict(protocol="sync", test_acc=round(sync.test_acc, 4),
                     final_loss=round(sync.losses[-1], 4), mbytes_pushed="n/a"))
    for proto, kw in (("epoch_fixed", dict(staleness=2)),
                      ("epoch_fixed", dict(staleness=4)),
                      ("epoch_adaptive", dict(staleness=4)),
                      ("variation", dict(eps_v=0.05)),
                      ("pipegcn", dict(lr=0.3))):
        r = full_graph_train(g, protocol=proto, epochs=50, **kw)
        rows.append(dict(protocol=f"{proto}:{kw}", test_acc=round(r.test_acc, 4),
                         final_loss=round(r.losses[-1], 4),
                         mbytes_pushed=round(r.bytes_pushed / 1e6, 3)))
    gap = max(abs(r["test_acc"] - rows[0]["test_acc"]) for r in rows[1:])
    return rows, f"max_acc_gap_vs_sync={gap:.4f}"
