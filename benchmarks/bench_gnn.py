"""GNN-side benchmarks — one per survey table/figure analog.

Each function returns (rows, derived_summary): rows are printable dicts; the
summary is one line for the CSV contract in run.py.

``python benchmarks/bench_gnn.py --json`` seeds the step-pipeline perf
trajectory: it writes BENCH_step_pipeline.json (blocking vs thread-pipelined
vs PROCESS-pipelined epoch wall-clock, chunked vs monolithic exchange peak
bytes + step time, measured on forced-host 4/8-device subprocesses).  The
thread pipeline's wall comparison is capacity-gated (it needs a spare core
for the sampler thread); the process pipeline's is NOT — its workers hold
their own GILs and its finished-batch LRU reuses the deterministic batches
across epochs, so process-pipelined <= blocking is asserted on any host.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import full_graph_train, powerlaw_graph, sbm_graph
from repro.core.partition import PARTITIONERS
from repro.core.protocols import PROTOCOL_COSTS
from repro.core.sampling import (
    FIFOCache,
    analysis_cache,
    csp_sample,
    importance_cache,
    node_wise_sample,
    presampling_cache,
    pull_based_sample,
    simulate_hit_ratio,
    skewed_weighted_sample,
    static_degree_cache,
)


def bench_partition() -> Tuple[List[Dict], str]:
    """Survey §4.2 table: partition quality (cut, balance, train balance,
    comm volume) per partitioner, on a community graph and a power-law graph."""
    rows = []
    for gname, g in (("sbm", sbm_graph(400, num_blocks=8, p_in=0.06, p_out=0.003, seed=0)),
                     ("powerlaw", powerlaw_graph(400, avg_degree=10, seed=0))):
        for name in ("hash", "range", "ldg", "pagraph", "block", "bytegnn", "metis_like"):
            t0 = time.perf_counter()
            part = PARTITIONERS[name](g, 8)
            dt = time.perf_counter() - t0
            rows.append(dict(graph=gname, partitioner=name,
                             cut=round(part.edge_cut_fraction(g), 4),
                             balance=round(part.vertex_balance(), 3),
                             train_balance=round(part.train_balance(g), 3),
                             comm_rows=part.communication_volume(g),
                             seconds=round(dt, 3)))
    balanced = [r for r in rows if r["graph"] == "sbm" and r["balance"] < 1.5]
    best = min(balanced, key=lambda r: r["cut"])
    return rows, f"best_balanced_sbm_cut={best['partitioner']}:{best['cut']}"


def bench_cache() -> Tuple[List[Dict], str]:
    """Survey §5.1: hit ratio per cache policy (PaGraph/AliGraph/GNNLab/
    SALIENT++/BGL claims) at several capacities on a power-law graph."""
    g = powerlaw_graph(600, avg_degree=12, seed=1)
    rng = np.random.default_rng(0)
    train = np.where(g.train_mask)[0]

    def stream(seed=0):
        r = np.random.default_rng(seed)
        for _ in range(30):
            batch = r.choice(train, 16, replace=False)
            yield node_wise_sample(g, batch, (4, 4), r).layer_vertices[0]

    rows = []
    for cap_frac in (0.05, 0.15, 0.3):
        cap = int(cap_frac * g.num_vertices)
        random_ids = rng.choice(g.num_vertices, cap, replace=False)
        policies = {
            "random": lambda: random_ids,
            "degree(PaGraph)": lambda: static_degree_cache(g, cap),
            "importance(AliGraph)": lambda: importance_cache(g, cap),
            "presampling(GNNLab)": lambda: presampling_cache(g, cap),
            "analysis(SALIENT++)": lambda: analysis_cache(g, cap),
        }
        for name, fn in policies.items():
            hr = simulate_hit_ratio(fn(), stream())
            rows.append(dict(capacity=cap, policy=name, hit_ratio=round(hr, 4)))
        fifo = FIFOCache(cap)
        rows.append(dict(capacity=cap, policy="fifo(BGL)",
                         hit_ratio=round(fifo.run(stream()), 4)))
    top = max(rows, key=lambda r: r["hit_ratio"])
    return rows, f"best={top['policy']}@{top['capacity']}:{top['hit_ratio']}"


def bench_distributed_sampling() -> Tuple[List[Dict], str]:
    """Survey §5.1: DSP's CSP vs pull-based bytes; skewed-sampling locality."""
    g = powerlaw_graph(600, avg_degree=12, seed=2)
    part = PARTITIONERS["hash"](g, 8)
    rng = np.random.default_rng(0)
    targets = np.arange(256)
    rows = []
    _, pull = pull_based_sample(g, part, 0, targets, fanout=5, rng=rng)
    _, push = csp_sample(g, part, 0, targets, fanout=5, rng=rng)
    rows.append(dict(method="pull(DistDGL)", bytes=pull.total()))
    rows.append(dict(method="csp(DSP)", bytes=push.total(),
                     reduction=round(1 - push.total() / max(pull.total(), 1), 3)))
    for s in (1.0, 2.0, 4.0, 8.0):
        _, st, loc = skewed_weighted_sample(g, part, 0, targets, 5, s,
                                            np.random.default_rng(1))
        rows.append(dict(method=f"skewed(s={s})", bytes=st.total(),
                         locality=round(loc, 3)))
    return rows, f"csp_reduction={rows[1]['reduction']}"


def bench_protocol_costs() -> Tuple[List[Dict], str]:
    """Survey §7.1: per-protocol communication volume per layer."""
    g = powerlaw_graph(500, avg_degree=10, seed=3)
    part = PARTITIONERS["metis_like"](g, 8)
    rows = []
    for name, fn in PROTOCOL_COSTS.items():
        c = fn(g, part, 64)
        rows.append(dict(protocol=name, bytes_per_layer=c.bytes_per_layer,
                         messages=c.messages_per_layer))
    b = next(r for r in rows if r["protocol"] == "broadcast")["bytes_per_layer"]
    p = next(r for r in rows if r["protocol"] == "p2p")["bytes_per_layer"]
    return rows, f"p2p_vs_broadcast={p / max(b, 1):.3f}"


def bench_staleness() -> Tuple[List[Dict], str]:
    """Survey §7.2 / Table 3: accuracy + bytes pushed per staleness model
    (PipeGCN/SANCUS claim: bounded staleness ~ sync accuracy, less comm)."""
    g = sbm_graph(250, num_blocks=4, p_in=0.08, p_out=0.004, seed=4)
    rows = []
    sync = full_graph_train(g, epochs=50)
    rows.append(dict(protocol="sync", test_acc=round(sync.test_acc, 4),
                     final_loss=round(sync.losses[-1], 4), mbytes_pushed="n/a"))
    for proto, kw in (("epoch_fixed", dict(staleness=2)),
                      ("epoch_fixed", dict(staleness=4)),
                      ("epoch_adaptive", dict(staleness=4)),
                      ("variation", dict(eps_v=0.05)),
                      ("pipegcn", dict(lr=0.3))):
        r = full_graph_train(g, protocol=proto, epochs=50, **kw)
        rows.append(dict(protocol=f"{proto}:{kw}", test_acc=round(r.test_acc, 4),
                         final_loss=round(r.losses[-1], 4),
                         mbytes_pushed=round(r.bytes_pushed / 1e6, 3)))
    gap = max(abs(r["test_acc"] - rows[0]["test_acc"]) for r in rows[1:])
    return rows, f"max_acc_gap_vs_sync={gap:.4f}"


def bench_trainable_embeddings() -> Tuple[List[Dict], str]:
    """ISSUE 6: the wire cost of making layer-0 rows TRAINABLE embeddings.

    Full-graph: `embedding_grad_bytes_per_step` (the transpose of one
    layer-0-width exchange) per execution model x partitioner — p2p returns
    each halo cotangent to its owner once, so its advantage over the
    broadcast/ring reduce-scatter grows with partition quality.  Mini-batch:
    `embedding_update_bytes` with and without the hot-row cache overlay —
    cached rows stop costing per-miss fetches but start costing the fixed
    2*overlay refresh/grad rows per step, so the overlay only pays for
    itself once the hit rows it absorbs exceed that rent."""
    from repro.core.partition.cost_models import embedding_grad_bytes_per_step
    from repro.core.sampling.distributed import embedding_update_bytes

    g = powerlaw_graph(600, avg_degree=12, seed=5)
    k, D = 8, 64
    nb = -(-g.num_vertices // k)
    rows = []
    for pname in ("hash", "metis_like"):
        part = PARTITIONERS[pname](g, k)
        per_exec = {
            ex: embedding_grad_bytes_per_step(g, ex, (D,), k=k, part=part,
                                              nb=nb)
            for ex in ("broadcast", "ring", "p2p")}
        for ex, b in per_exec.items():
            rows.append(dict(mode="full_graph", partitioner=pname,
                             execution=ex, embed_grad_bytes=b,
                             vs_broadcast=round(
                                 b / max(per_exec["broadcast"], 1), 3)))

    part = PARTITIONERS["metis_like"](g, k)
    train = np.where(g.train_mask)[0]
    rng = np.random.default_rng(0)
    frontiers = []
    for _ in range(30):
        batch = rng.choice(train, 16, replace=False)
        frontiers.append(node_wise_sample(g, batch, (4, 4),
                                          rng).layer_vertices[0])
    for cap_frac in (0.0, 0.05, 0.15):
        cap = int(cap_frac * g.num_vertices)
        cached = (frozenset(int(v) for v in static_degree_cache(g, cap))
                  if cap else frozenset())
        total = sum(embedding_update_bytes(part, 0, f, D, cached_ids=cached,
                                           overlay_rows=cap)
                    for f in frontiers)
        rows.append(dict(mode="node_wise", partitioner="metis_like",
                         cache_capacity=cap,
                         embed_grad_bytes=total // len(frontiers)))
    fg = {r["execution"]: r["embed_grad_bytes"] for r in rows
          if r["mode"] == "full_graph" and r["partitioner"] == "metis_like"}
    mb = {r["cache_capacity"]: r["embed_grad_bytes"] for r in rows
          if r["mode"] == "node_wise"}
    best_cap = min(mb, key=mb.get)
    return rows, (f"p2p_vs_broadcast={fg['p2p'] / max(fg['broadcast'], 1):.3f}"
                  f" best_overlay_cap={best_cap}")


# ---------------------------------------------------------------------------
# ISSUE 4: the pipelined hot path — blocking vs pipelined epoch wall-clock
# and chunked vs monolithic exchange, measured for real on forced-host
# devices (fresh subprocesses so the parent keeps its single device).
# ---------------------------------------------------------------------------

_PIPELINE_PROBE = r"""
import json, os, time
import jax
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.execution.minibatch_pipeline import pipelined_wall_model
from repro.core.execution.pipeline_exchange import gathered_table_peak_bytes
from repro.core.graph import sbm_graph

n_dev = len(jax.devices())
g = sbm_graph(256, num_blocks=8, p_in=0.06, p_out=0.01, seed=0)

# -- blocking vs thread-pipelined vs process-pipelined epoch ---------------
cfg = EngineConfig(execution="broadcast", batching="node_wise", batch_size=16,
                   fanouts=(4, 4), hidden=32, lr=0.3, exchange_chunks=4,
                   prefetch_depth=2, num_sample_workers=2)
eng = DistGNNEngine(g, cfg=cfg)
# warm the one jit compile, the host caches, and every schedule path — the
# process warm-up also starts the persistent worker pool + shm ring, so
# pool startup is paid OUTSIDE the timed region (as in real training, where
# one pool serves the whole run)
eng.run_epoch_minibatch(2)
eng.run_epoch_minibatch(2, schedule="pipelined")
eng.run_epoch_minibatch(2, schedule="pipelined", prefetch_mode="process")
NB, TRIALS = 12, 3
trials = []
for _ in range(TRIALS):  # interleaved: all arms see the same machine load
    _, lb, tb = eng.run_epoch_minibatch(NB, schedule="conventional")
    _, lt, tt = eng.run_epoch_minibatch(NB, schedule="pipelined")
    _, lp, tp = eng.run_epoch_minibatch(NB, schedule="pipelined",
                                        prefetch_mode="process")
    assert lt == lb, "thread-pipelined epoch must be bitwise-identical"
    assert lp == lb, "process-pipelined epoch must be bitwise-identical"
    trials.append((tb, tt, tp))
eng.close_prefetch_pool()
blocking = min((b for b, _, _ in trials), key=lambda t: t.wall)
threaded = min((t for _, t, _ in trials), key=lambda t: t.wall)
processed = min((p for _, _, p in trials), key=lambda t: t.wall)
model = pipelined_wall_model(threaded, NB)

# The thread pipeline's lanes really ran concurrently: the measured wall
# must sit below the serial sum of the run's OWN measured stage times.
# This is the machine-independent overlap evidence; the thread wall-vs-
# blocking comparison additionally needs a spare core beyond the forced
# host devices (an oversubscribed host serializes the lanes through GIL +
# core contention and can make the thread pipeline slower than blocking —
# recorded either way, gated by overlap_capacity_limited).
assert threaded.wall <= 0.95 * threaded.busy(), (
    "no measured overlap", threaded.wall, threaded.busy())
thread_capacity_limited = (os.cpu_count() or 1) < n_dev + 1
if not thread_capacity_limited:
    assert threaded.wall <= blocking.wall, (
        "thread-pipelined epoch slower than blocking with spare cores",
        threaded.wall, blocking.wall)
# The PROCESS pipeline has no capacity escape hatch: its producers hold
# their own GILs, the trainer defers every device sync to epoch end, and
# the persistent pool's finished-batch LRU serves repeat epochs without
# resampling (batches are deterministic in (seed, step, device) — pure
# functions of the step), so it must beat the per-step-syncing blocking
# epoch on ANY host, 1 core up.
assert processed.wall <= blocking.wall, (
    "process-pipelined epoch slower than blocking",
    processed.wall, blocking.wall)

# -- chunked vs monolithic full-graph broadcast exchange ------------------
steps = {}
for chunks in (1, 4):
    e = DistGNNEngine(g, cfg=EngineConfig(execution="broadcast", hidden=32,
                                          lr=0.3, exchange_chunks=chunks))
    step = e.make_step()
    state = e.init_state()
    state, m, _ = step(state)
    jax.block_until_ready(m["loss"])  # compile + first step
    t0 = time.perf_counter()
    for _ in range(5):
        state, m, _ = step(state)
    jax.block_until_ready(m["loss"])
    steps[chunks] = dict(
        step_seconds=(time.perf_counter() - t0) / 5,
        gathered_table_peak_bytes=gathered_table_peak_bytes(
            e.Vp, max(e.dims[:-1]), chunks))

print("BENCH_JSON " + json.dumps(dict(
    devices=n_dev, num_batches=NB, host_cores=os.cpu_count(),
    blocking_epoch_seconds=blocking.wall,
    thread_pipelined=dict(
        epoch_seconds=threaded.wall,
        busy_seconds=threaded.busy(),
        overlap_ratio=threaded.wall / max(threaded.busy(), 1e-9),
        lane_seconds=dict(sample=threaded.sample, extract=threaded.extract,
                          train=threaded.train),
        wall_model_seconds=model,
        overlap_capacity_limited=thread_capacity_limited),
    process_pipelined=dict(
        epoch_seconds=processed.wall,
        busy_seconds=processed.busy(),
        lane_seconds=dict(sample=processed.sample, extract=processed.extract,
                          train=processed.train),
        num_sample_workers=2,
        overlap_capacity_limited=False),
    exchange=dict(monolithic=steps[1], chunked_4=steps[4]))))
"""


def bench_step_pipeline(out_dir: str = "experiments/dryrun"
                        ) -> Tuple[List[Dict], str]:
    """ISSUE 4 + ISSUE 9 perf trajectory: measure blocking vs
    thread-pipelined vs PROCESS-pipelined epochs (and the chunked exchange
    against the monolithic one) on forced-host 4/8-device subprocesses;
    write BENCH_step_pipeline.json.

    Asserted per device count: both pipelined epochs' losses == blocking
    losses bitwise, the thread pipeline's wall sits below the serial sum of
    its own measured lanes (real overlap), and — on hosts with at least one
    spare core beyond the forced devices — thread-pipelined wall <=
    blocking wall.  On an oversubscribed host (cores <= devices) the XLA
    compute threads, the collective spin-waits, and the sampler thread
    fight for the same cores, so the thread wall comparison is recorded
    with ``overlap_capacity_limited: true`` instead of asserted.  The
    PROCESS pipeline carries no such gate: its sampler workers hold their
    own GILs, the trainer syncs once per epoch instead of per step, and the
    persistent pool's finished-batch LRU exploits the engine's
    deterministic sampling to serve repeat epochs without resampling, so
    process-pipelined wall <= blocking wall is asserted unconditionally."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    result = dict(graph="sbm_256", devices={})
    rows = []
    for n_dev in (4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
        env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                             + env.get("PYTHONPATH", ""))
        proc = subprocess.run([sys.executable, "-c", _PIPELINE_PROBE],
                              capture_output=True, text=True, timeout=900,
                              env=env)
        if proc.returncode != 0:
            raise RuntimeError(
                f"pipeline probe failed on {n_dev} devices:\n"
                f"{proc.stdout}\n{proc.stderr[-3000:]}")
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("BENCH_JSON ")][-1]
        entry = json.loads(line[len("BENCH_JSON "):])
        result["devices"][str(n_dev)] = entry
        ex = entry["exchange"]
        th, pr = entry["thread_pipelined"], entry["process_pipelined"]
        rows.append(dict(
            devices=n_dev,
            blocking_s=round(entry["blocking_epoch_seconds"], 4),
            thread_s=round(th["epoch_seconds"], 4),
            process_s=round(pr["epoch_seconds"], 4),
            thread_speedup=round(entry["blocking_epoch_seconds"]
                                 / max(th["epoch_seconds"], 1e-9), 3),
            process_speedup=round(entry["blocking_epoch_seconds"]
                                  / max(pr["epoch_seconds"], 1e-9), 3),
            overlap_ratio=round(th["overlap_ratio"], 3),
            thread_capacity_limited=th["overlap_capacity_limited"],
            process_capacity_limited=pr["overlap_capacity_limited"],
            chunk_peak_reduction=round(
                ex["monolithic"]["gathered_table_peak_bytes"]
                / ex["chunked_4"]["gathered_table_peak_bytes"], 2),
            chunked_step_s=round(ex["chunked_4"]["step_seconds"], 5),
            monolithic_step_s=round(ex["monolithic"]["step_seconds"], 5)))
    # write the artifact BEFORE asserting so a failed claim leaves evidence
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "BENCH_step_pipeline.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=float)
    for r in rows:
        assert r["overlap_ratio"] <= 0.95, (
            f"pipelined lanes did not overlap on {r['devices']} devices: {r}")
        if not r["thread_capacity_limited"]:
            assert r["thread_s"] <= r["blocking_s"], (
                f"thread-pipelined epoch must not be slower than blocking "
                f"on {r['devices']} devices: {r}")
        assert not r["process_capacity_limited"], r
        assert r["process_s"] <= r["blocking_s"], (
            f"process-pipelined epoch must not be slower than blocking "
            f"on {r['devices']} devices (no capacity escape hatch): {r}")
        assert r["chunk_peak_reduction"] >= 2, r
    best = max(rows, key=lambda r: r["process_speedup"])
    return rows, (f"process_speedup@{best['devices']}dev="
                  f"{best['process_speedup']} artifact={path}")


# ---------------------------------------------------------------------------
# ISSUE 8: run-wide telemetry — traced vs untraced epoch wall (the overhead
# contract), the per-stage wall breakdown, workload-imbalance ratios, and the
# trace-accounting cross-checks, measured on a forced-host 4-device
# subprocess.  Artifact: BENCH_telemetry.json, written before any assertion.
# ---------------------------------------------------------------------------

_TELEMETRY_PROBE = r"""
import json, time
import jax
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph
from repro.core.serving import GNNQueryEngine
from repro.core.telemetry import Telemetry
from repro.launch.hlo_analysis import executable_summary

n_dev = len(jax.devices())
g = sbm_graph(256, num_blocks=8, p_in=0.06, p_out=0.01, seed=0)
cfg = EngineConfig(execution="p2p", batching="node_wise", batch_size=16,
                   fanouts=(4, 4), hidden=32, lr=0.3,
                   cache_policy="static_degree", cache_capacity=32)
eng = DistGNNEngine(g, cfg=cfg)
eng.run_epoch_minibatch(2)  # warm: the one jit compile + host caches
NB, TRIALS = 10, 5
untraced, traced = [], []
tel = state = None
for _ in range(TRIALS):  # interleaved arms: both see the same machine load
    eng.enable_telemetry(Telemetry(enabled=False))
    t0 = time.perf_counter()
    eng.run_epoch_minibatch(NB)
    untraced.append(time.perf_counter() - t0)
    tel = eng.enable_telemetry(Telemetry())  # fresh trace per traced trial
    t0 = time.perf_counter()
    state, _, times = eng.run_epoch_minibatch(NB)
    traced.append(time.perf_counter() - t0)

# serve through the SAME trace: flush latency histogram + coalescing stats
# (comm_stats keeps accumulating — the trace contract must still balance)
qe = GNNQueryEngine(eng, state["params"])
for q in ([1, 2, 3], [3, 4], [10, 11, 12, 13]):
    qe.submit(q)
qe.flush()
qe.query([5, 6])

# static executable facts enrich the run summary (hlo_analysis)
tel.attach_executable("minibatch_train_step",
                      executable_summary(eng.lower_minibatch_step().compile()))

# microbench the tracer itself: the per-span bookkeeping cost in isolation
N = 20000
t0 = time.perf_counter()
for i in range(N):
    with tel.span("microbench", step=i, device=0):
        pass
span_cost = (time.perf_counter() - t0) / N

trace = tel.chrome_trace()
events = json.loads(json.dumps(trace))["traceEvents"]
xev = [e for e in events if e["ph"] == "X"]
schema_ok = all(set(("name", "ph", "ts", "dur", "pid", "tid")) <= set(e)
                for e in xev)
exchange_bytes = sum(e["args"].get("bytes", 0) for e in xev
                     if e["name"] == "exchange")
summary = tel.run_summary()
u, t = min(untraced), min(traced)
print("BENCH_JSON " + json.dumps(dict(
    devices=n_dev, num_batches=NB, trials=TRIALS,
    untraced_epoch_seconds=u, traced_epoch_seconds=t,
    overhead_ratio=max(0.0, t - u) / u,
    span_cost_seconds=span_cost,
    spans_per_epoch=summary["spans"]["count"],
    stage_seconds=summary["spans"]["seconds_by_name"],
    stage_times=dict(sample=times.sample, extract=times.extract,
                     train=times.train, wall=times.wall),
    imbalance=summary["imbalance"],
    exchange_span_bytes=exchange_bytes,
    comm_total_bytes=eng.comm_stats.total(),
    trace_event_count=len(xev), trace_schema_ok=schema_ok,
    serve=dict(
        flush_p50_ms=tel.histogram("serve.flush_latency_s").percentile(50)
        * 1e3,
        flush_p99_ms=tel.histogram("serve.flush_latency_s").percentile(99)
        * 1e3,
        queries=tel.metrics.counter_total("serve.queries"),
        rounds=tel.metrics.counter_total("serve.rounds"),
        targets_requested=tel.metrics.counter_total(
            "serve.targets_requested"),
        targets_unique=tel.metrics.counter_total("serve.targets_unique")),
    executables=summary["executables"]), default=float))
"""


def bench_telemetry(out_dir: str = "experiments/dryrun"
                    ) -> Tuple[List[Dict], str]:
    """ISSUE 8 observability contract, measured on a forced-host 4-device
    subprocess and written to BENCH_telemetry.json BEFORE any assertion:

    - telemetry overhead: min traced epoch wall vs min untraced epoch wall
      over interleaved trials, asserted < 5% (plus the isolated per-span
      bookkeeping cost for context);
    - per-stage wall breakdown (span seconds by stage) and the workload-
      imbalance report (max/mean per stage across devices);
    - trace accounting: summed exchange-span bytes == CommStats.total()
      EXACTLY, and the Chrome trace-event schema round-trips."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", _TELEMETRY_PROBE],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"telemetry probe failed:\n{proc.stdout}\n"
                           f"{proc.stderr[-3000:]}")
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("BENCH_JSON ")][-1]
    entry = json.loads(line[len("BENCH_JSON "):])
    # write the artifact BEFORE asserting so a failed claim leaves evidence
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "BENCH_telemetry.json")
    with open(path, "w") as f:
        json.dump(entry, f, indent=1, default=float)
    assert entry["overhead_ratio"] < 0.05, (
        f"traced epoch must cost < 5% over untraced: "
        f"{entry['overhead_ratio']:.3f} "
        f"(untraced {entry['untraced_epoch_seconds']:.3f}s, "
        f"traced {entry['traced_epoch_seconds']:.3f}s)")
    assert entry["exchange_span_bytes"] == entry["comm_total_bytes"], entry
    assert entry["trace_schema_ok"] and entry["trace_event_count"] > 0
    stages = entry["imbalance"]["metrics"]
    assert stages, "imbalance report is empty"
    for name, rec in stages.items():
        assert rec["max_over_mean"] >= 1.0 or rec["mean"] == 0, (name, rec)
    rows = [dict(
        devices=entry["devices"],
        untraced_s=round(entry["untraced_epoch_seconds"], 4),
        traced_s=round(entry["traced_epoch_seconds"], 4),
        overhead=round(entry["overhead_ratio"], 4),
        span_cost_us=round(entry["span_cost_seconds"] * 1e6, 2),
        spans=entry["spans_per_epoch"],
        exchange_bytes=entry["exchange_span_bytes"],
        imbalance_stages=len(stages))]
    return rows, (f"telemetry_overhead={rows[0]['overhead']}"
                  f" artifact={path}")


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="run the step-pipeline bench and write "
                    "BENCH_step_pipeline.json")
    ap.add_argument("--telemetry", action="store_true",
                    help="run the telemetry bench and write "
                    "BENCH_telemetry.json")
    ap.add_argument("--bench-partition-families", action="store_true",
                    help="run the partition-families cost bench (edge-cut "
                    "halo vs vertex-cut replica-sync vs hybrid degree-"
                    "threshold sweep across graphs x chips) and write "
                    "BENCH_partition_families.json — asserts vertex-cut "
                    "beats edge-cut critical path on the base power-law "
                    "256-chip point and the best hybrid threshold beats "
                    "BOTH pure families on the double-size one")
    ap.add_argument("--vertices", type=int, default=2048,
                    help="partition-families bench: base synthetic graph "
                    "size (the hybrid regime point doubles it)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    if not (args.json or args.telemetry or args.bench_partition_families):
        ap.error("pass --json, --telemetry and/or --bench-partition-families "
                 "(the CSV benches run via benchmarks/run.py)")
    if args.bench_partition_families:
        from repro.configs.gcn_paper import CONFIG as GNN_CFG
        from repro.launch.dryrun_gnn import bench_partition_families

        dims = ([GNN_CFG.feature_dim]
                + [GNN_CFG.hidden_dim] * (GNN_CFG.num_layers - 1)
                + [GNN_CFG.num_classes])
        path = bench_partition_families(args.out, dims,
                                        vertices=args.vertices)
        print(f"partition-families bench -> {path}")
    if args.json:
        rows, derived = bench_step_pipeline(args.out)
        for r in rows:
            print(r)
        print(derived)
    if args.telemetry:
        rows, derived = bench_telemetry(args.out)
        for r in rows:
            print(r)
        print(derived)


if __name__ == "__main__":
    main()
