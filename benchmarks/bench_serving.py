"""ISSUE 7 serving benchmark: the two inference tiers measured for real.

``python benchmarks/bench_serving.py --json`` writes BENCH_serving.json
(same artifact contract as BENCH_step_pipeline.json): a forced-host
4-device subprocess measures

* the THROUGHPUT tier — layer-wise full-graph sweep wall-clock at two
  vertex counts, each sweep oracle-checked (<= 1e-4) and its
  CommStats.inference_bytes cross-checked EXACTLY against the standalone
  ``cost_models.inference_bytes_per_sweep``;
* the LATENCY tier — a GNNQueryEngine query stream: qps, p50/p99 latency,
  and the serve-step compile count (must be exactly 1).

The artifact is written BEFORE asserting so a failed claim leaves evidence.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List, Tuple

_SERVING_PROBE = r"""
import json, time
import jax
import numpy as np
from repro.core.engine import DistGNNEngine, EngineConfig
from repro.core.graph import sbm_graph
from repro.core.partition.cost_models import inference_bytes_per_sweep
from repro.core.serving import GNNQueryEngine

n_dev = len(jax.devices())

# -- throughput tier: sweep wall vs vertex count --------------------------
sweeps = []
for V in (256, 512):
    g = sbm_graph(V, num_blocks=8, p_in=0.05, p_out=0.003, seed=0)
    eng = DistGNNEngine(g, cfg=EngineConfig(execution="p2p", hidden=32,
                                            lr=0.3))
    state = eng.init_state()
    step = eng.make_step()
    for _ in range(3):
        state, _, _ = step(state)
    params = state["params"]
    H = eng.infer_full_graph(params=params)  # compile + first sweep
    jax.block_until_ready(H)
    t0 = time.perf_counter()
    N = 5
    for _ in range(N):
        H = eng.infer_full_graph(params=params)
    jax.block_until_ready(H)
    wall = (time.perf_counter() - t0) / N
    emb = eng.global_embeddings(H)
    ref = eng.global_embeddings(eng.infer_full_graph(params=params,
                                                     reference=True))
    err = float(np.max(np.abs(emb - ref)))
    expect = (N + 1) * inference_bytes_per_sweep(
        "p2p", eng.dims, model="gcn", family="edge_cut", g=g, part=eng.part)
    sweeps.append(dict(vertices=V, sweep_seconds=wall, oracle_err=err,
                       inference_bytes=int(eng.comm_stats.inference_bytes),
                       cost_model_bytes=int(expect),
                       bytes_match=eng.comm_stats.inference_bytes == expect,
                       compiles=eng._jit_infer._cache_size()))

# -- latency tier: query stream -------------------------------------------
g = sbm_graph(512, num_blocks=8, p_in=0.05, p_out=0.003, seed=0)
eng = DistGNNEngine(g, cfg=EngineConfig(
    execution="p2p", batching="node_wise", batch_size=16, fanouts=(4, 4),
    hidden=32, lr=0.3, cache_policy="static_degree", cache_capacity=32))
state, _, _ = eng.run_epoch_minibatch(4)
qe = GNNQueryEngine(eng, state["params"])
rng = np.random.default_rng(0)
qe.query(rng.choice(g.num_vertices, 8, replace=False))  # warmup compile
qe.stats.latencies_s.clear()
qe.stats.queries = 0
NQ = 24
for _ in range(NQ):
    qe.query(rng.choice(g.num_vertices, 8, replace=False))
queries = dict(num_queries=NQ, targets_per_query=8,
               qps=qe.stats.qps(),
               p50_ms=qe.stats.percentile_ms(50),
               p99_ms=qe.stats.percentile_ms(99),
               rounds=qe.stats.rounds, compiles=qe.num_compiles())

print("BENCH_JSON " + json.dumps(dict(devices=n_dev, sweeps=sweeps,
                                      queries=queries)))
"""


def bench_serving(out_dir: str = "experiments/dryrun"
                  ) -> Tuple[List[Dict], str]:
    """Measure both serving tiers on a forced-host 4-device subprocess and
    write BENCH_serving.json; assert one compile per tier, oracle err
    <= 1e-4, bytes == the standalone cost model, qps > 0."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", _SERVING_PROBE],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"serving probe failed:\n{proc.stdout}\n"
                           f"{proc.stderr[-3000:]}")
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("BENCH_JSON ")][-1]
    result = json.loads(line[len("BENCH_JSON "):])
    # write the artifact BEFORE asserting so a failed claim leaves evidence
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=float)
    rows = []
    for s in result["sweeps"]:
        rows.append(dict(tier="sweep", vertices=s["vertices"],
                         sweep_s=round(s["sweep_seconds"], 4),
                         oracle_err=s["oracle_err"],
                         bytes_match=s["bytes_match"],
                         compiles=s["compiles"]))
        assert s["oracle_err"] <= 1e-4, s
        assert s["bytes_match"], (
            f"CommStats.inference_bytes {s['inference_bytes']} != cost model "
            f"{s['cost_model_bytes']}")
        assert s["compiles"] == 1, s
    q = result["queries"]
    rows.append(dict(tier="queries", qps=round(q["qps"], 1),
                     p50_ms=round(q["p50_ms"], 2),
                     p99_ms=round(q["p99_ms"], 2),
                     rounds=q["rounds"], compiles=q["compiles"]))
    assert q["compiles"] == 1, "serve step recompiled"
    assert q["qps"] > 0, q
    return rows, (f"qps={q['qps']:.1f} p99_ms={q['p99_ms']:.2f} "
                  f"artifact={path}")


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="run the serving bench and write BENCH_serving.json")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    if not args.json:
        ap.error("pass --json")
    rows, derived = bench_serving(args.out)
    for r in rows:
        print(r)
    print(derived)


if __name__ == "__main__":
    main()
