"""Roofline report generator (deliverable g): reads the dry-run JSON artifacts
and emits the §Roofline table — three terms, dominant bottleneck, MODEL_FLOPS
ratio, and a one-line recommendation per (arch x shape x mesh).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional, Tuple

from repro.utils import human_bytes

RECOMMEND = {
    "compute": "increase arithmetic utilization: larger per-chip batch, fuse "
               "elementwise chains, MXU-aligned tiles",
    "memory": "cut HBM traffic: quantize weights/KV (int8/fp8), fuse reads, "
              "GQA-native decode (skip KV head expansion)",
    "collective": "cut bytes on ICI: bf16/int8 collectives, reduce-scatter + "
                  "seq-parallel instead of all-reduce, overlap a2a with "
                  "expert compute, fewer dispatch chunks",
}


def load_results(out_dir: str) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def roofline_table(out_dir: str = "experiments/dryrun", mesh: Optional[str] = "pod16x16",
                   tag: str = "") -> Tuple[List[Dict], str]:
    rows = []
    for r in load_results(out_dir):
        if r.get("status") != "ok":
            if r.get("status") == "skipped":
                rows.append(dict(arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                                 status="skipped", reason=r.get("reason", "")))
            continue
        if mesh and r["mesh"] != mesh:
            continue
        if (r.get("tag") or "") != tag:
            continue
        rl = r["roofline"]
        rows.append(dict(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
            compute_ms=round(rl["compute_s"] * 1e3, 2),
            memory_ms=round(rl["memory_s"] * 1e3, 2),
            collective_ms=round(rl["collective_s"] * 1e3, 2),
            dominant=rl["dominant"],
            useful_ratio=round(rl["useful_ratio"], 3),
            hlo_flops_raw=f"{rl['hlo_flops_raw']:.2e}",
            analytic_flops=f"{rl['analytic_flops']:.2e}",
            coll_bytes=human_bytes(r["collective_bytes_per_device"]),
            peak_args=human_bytes(r["memory"]["argument_bytes_per_device"]),
            temp=human_bytes(r["memory"]["temp_bytes_per_device"]),
            fix=RECOMMEND[rl["dominant"]],
        ))
    ok = [r for r in rows if "dominant" in r]
    doms = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    return rows, f"{len(ok)} pairs, dominant terms: {doms}"


def worst_pairs(out_dir: str = "experiments/dryrun", n: int = 5) -> List[Dict]:
    """Hillclimb candidates: worst dominant-term magnitude, most
    collective-bound, and most representative pairs."""
    rows, _ = roofline_table(out_dir)
    ok = [r for r in rows if "dominant" in r]
    ok.sort(key=lambda r: -max(r["compute_ms"], r["memory_ms"], r["collective_ms"]))
    return ok[:n]


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    rows, summary = roofline_table(args.out, mesh=args.mesh, tag=args.tag)
    if rows:
        keys = [k for k in rows[-1] if k != "fix"]
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r.get(k, "")) for k in keys))
    print("#", summary)


if __name__ == "__main__":
    main()
